"""Deliverable (g): the roofline table from the dry-run JSONs
(``experiments/dryrun/*.json``, written by ``experiments/run_dryruns.py``).
One row per dry-run artifact — every arch (including the paper's own CLIP
towers under the contrastive objective), every mesh that was swept.

A missing or empty ``experiments/dryrun/`` directory is an ERROR, never an
empty table: ``run()`` raises (the ``benchmarks.run`` harness surfaces it
as an ERROR row) and the CLI exits nonzero with the command to fix it.
Historically this bench globbed a single LLM mesh and filtered to LM-only
shapes, so a fresh checkout silently produced zero roofline rows.

Also reports the loss-layer HBM-traffic model behind the ``loss_impl``
knob: the dense path moves the (B, B) f32 pair matrix through HBM ~8x
per step (dense ~= 8*B^2*4 bytes), the fused Pallas path streams it
through VMEM in tiles (~0 pair-matrix HBM bytes) — see
benchmarks/kernel_bench.py and repro/kernels/gcl_loss.py."""
import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN_DIR = os.path.join(ROOT, "experiments", "dryrun")

# global batch sizes the paper's limited-resource setting cares about;
# the single-device dense traffic 8*B^2*4 reported below scales as
# ~8*b*B*4 per device when row-sharded over K devices (b = B/K)
LOSS_TRAFFIC_B = (512, 1024, 2048, 4096)


def model_flops(d):
    """Analytic useful-flops estimate per device, for the ratio column.

    Train: ~6*N_active*tokens (fwd+bwd), contrastive or LM alike — the
    CLIP pair loss is O(B^2*d), negligible next to the towers at dry-run
    scale.  Prefill: 2*N*tokens.  Decode: 2*N per generated token."""
    from repro.configs.base import INPUT_SHAPES
    n = d["active_params"]
    chips = d["chips"]
    shape = INPUT_SHAPES[d["shape"]]
    if shape.kind == "train":
        return 6 * n * shape.global_batch * shape.seq_len / chips
    if shape.kind == "prefill":
        return 2 * n * shape.global_batch * shape.seq_len / chips
    return 2 * n * shape.global_batch / chips


def dryrun_rows():
    """One (name, 0.0, derived) row per dry-run artifact, any mesh.

    Raises FileNotFoundError when the sweep has not been run — callers
    must surface that, not render an empty table."""
    paths = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not paths:
        raise FileNotFoundError(
            f"no dry-run artifacts under {DRYRUN_DIR} — run "
            f"`python experiments/run_dryruns.py` (optionally --only rx) "
            f"to generate them; refusing to emit an empty roofline table")
    rows = []
    for fp in paths:
        try:
            d = json.load(open(fp))
        except ValueError as e:
            rows.append((f"roofline/{os.path.basename(fp)}", 0.0,
                         f"ERROR:unreadable:{e}"))
            continue
        mf = model_flops(d)
        ratio = mf / max(d["flops_per_device"], 1)
        r = d["roofline"]
        obj = d.get("objective", "lm")
        tag = f"/{obj}-{d['reduction']}" if obj != "lm" else ""
        rows.append((
            f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}{tag}", 0.0,
            f"bottleneck={r['bottleneck']};compute_s={r['compute_s']:.4f};"
            f"memory_s={r['memory_s']:.4f};"
            f"collective_s={r['collective_s']:.4f};"
            f"useful_flops_ratio={ratio:.3f}"))
    return rows


def loss_traffic_rows():
    from benchmarks.kernel_bench import pair_matrix_bytes
    rows = []
    for B in LOSS_TRAFFIC_B:
        dense = pair_matrix_bytes(B, "dense")
        rows.append((
            f"roofline/loss_pair_traffic/global_B={B}", 0.0,
            f"dense_hbm_bytes={dense};fused_hbm_bytes=0;"
            f"model=8*B^2*4_single_device_vs_vmem_tiles"))
    return rows


def run(steps=None, seed=None):
    return dryrun_rows() + loss_traffic_rows()


def main():
    try:
        rows = run()
    except FileNotFoundError as e:
        print(f"ERROR: {e}", file=sys.stderr)
        sys.exit(1)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
