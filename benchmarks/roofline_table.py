"""Deliverable (g): the roofline table from the dry-run JSONs
(experiments/dryrun/*.json).  One row per (arch x shape), single-pod."""
import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def model_flops(d, shape_kind):
    """6*N*D (dense) / 6*N_active*D (MoE) per device, for the ratio column."""
    n = d["active_params"]
    chips = d["chips"]
    if shape_kind == "train":
        tokens = 256 * 4096
        return 6 * n * tokens / chips
    if shape_kind == "prefill":
        return 2 * n * 32 * 32768 / chips
    # decode: one token
    bsz = 128 if "decode_32k" in d["shape"] else 1
    return 2 * n * bsz / chips


def run(steps=None, seed=None):
    rows = []
    for fp in sorted(glob.glob(os.path.join(ROOT, "experiments", "dryrun",
                                            "*16x16.json"))):
        d = json.load(open(fp))
        if d["mesh"] != "16x16":
            continue
        kind = ("train" if "train" in d["shape"]
                else "prefill" if "prefill" in d["shape"] else "decode")
        mf = model_flops(d, kind)
        ratio = mf / max(d["flops_per_device"], 1)
        r = d["roofline"]
        rows.append((
            f"roofline/{d['arch']}/{d['shape']}", 0.0,
            f"bottleneck={r['bottleneck']};compute_s={r['compute_s']:.4f};"
            f"memory_s={r['memory_s']:.4f};"
            f"collective_s={r['collective_s']:.4f};"
            f"useful_flops_ratio={ratio:.3f}"))
    return rows
