"""End-to-end train-step throughput: the f32 dense baseline vs the bf16
flash+fused fast path, plus the sharded-state (data, fsdp) step.

Times full optimizer steps (towers fwd/bwd + FCCO loss + AdamW update,
state donated) of the reduced ViT-B/32-family CLIP on synthetic data and
emits ``BENCH_step.json`` with one row per variant:

    f32-dense   : precision=f32,  impl=chunked, loss_impl=dense
    bf16-flash  : precision=bf16, impl=flash,   loss_impl=fused
    fsdp-d2f2   : f32-dense on a (data=2, fsdp=2) mesh — the sharded
                  train state (core.shard_state): reports steps/s plus
                  per-device param+moment bytes vs the replicated bytes.
                  Runs in a subprocess with 4 forced host devices (the
                  main process keeps 1), so per-step time measures the
                  correctness surface on CPU, not mesh speed.

On CPU the Pallas kernels run in interpret mode, so absolute times measure
the correctness surface, not TPU speed — the row schema and the loss-parity
column are the durable part (the ``delta_loss_vs_f32`` field bounds the
bf16 policy drift after ``steps`` real optimizer steps; it is null for the
sharded row, whose 4-shard loader draws differently-ordered batches).

Every row also carries modeled-cost columns from ``HLOCostModel`` over the
step's post-optimization HLO: ``modeled_flops``, ``modeled_hbm_bytes``,
``modeled_collective_bytes``, ``modeled_collective_counts``.  These are
machine-independent (a property of the lowered module, not the host), so
they regress meaningfully on CPU CI — ``benchmarks/modeled_cost.py``
snapshots them as goldens and the perf-model-smoke CI job fails on drift.

Run: PYTHONPATH=src python -m benchmarks.step_bench [--quick] [--steps N]
     [--out BENCH_step.json]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import fastclip as FC
from repro.core import shard_state as SS
from repro.core import train_step as TS
from repro.core.schedules import lr_warmup_cosine
from repro.data import ContrastiveDataset, ShardedLoader
from repro.launch.steps import donated_jit
from repro.optim import adamw

N_SAMPLES = 256
GLOBAL_BATCH = 64
SHARDED_MESH = (2, 2)    # (data, fsdp)
_ROW_MARK = "SHARDED-ROW "

VARIANTS = [
    # (name, precision, attention impl, loss impl)
    ("f32-dense", "f32", "chunked", "dense"),
    ("bf16-flash", "bf16", "flash", "fused"),
]


def _build(precision, impl, loss_impl, steps, seed=0, n_shards=1,
           fsdp=False):
    cfg = get_arch("clip-vitb32-cc12m").reduced()
    ds = ContrastiveDataset(n=N_SAMPLES, image_size=cfg.clip.image_size,
                            context_length=cfg.clip.context_length,
                            vocab_size=cfg.vocab_size, n_classes=32,
                            seed=seed)
    loader = ShardedLoader(ds, global_batch=GLOBAL_BATCH, seed=seed,
                           n_shards=n_shards)
    fc = FC.FastCLIPConfig(version="v3", n_samples=N_SAMPLES,
                           steps_per_epoch=loader.steps_per_epoch,
                           gamma_decay_epochs=2)
    tc = TS.TrainStepConfig(arch=cfg, fc=fc, optimizer=adamw(),
                            lr_fn=lr_warmup_cosine(1e-3, 4, max(steps, 8)),
                            wd=0.1, impl=impl, loss_impl=loss_impl,
                            precision=precision,
                            mesh_axes=SS.TRAIN_AXES if fsdp else None,
                            fsdp=fsdp)
    return tc, loader


def _time_steps(name, tc, loader, state, steps):
    """The shared compile/step timing loop + row assembly (identical
    protocol for the local variants and the sharded worker).

    The step is compiled ahead-of-time (``.lower().compile()``) so the
    same executable serves both the timing loop and the modeled-cost
    columns: its post-optimization HLO goes through ``HLOCostModel``
    (trip-count-aware flops / HBM bytes / collective counts — the numbers
    ``benchmarks.modeled_cost`` snapshots as goldens and CI gates on)."""
    from repro.roofline.hlo_cost import HLOCostModel

    jit_fn = donated_jit(TS.make_train_step(tc))
    compiled = None
    t_compile = t_steps = 0.0
    n_timed = 0
    losses = []
    for epoch, step, idx, batch in loader.steps(steps):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        idx = jnp.asarray(idx)
        if compiled is None:
            t0 = time.perf_counter()
            compiled = jit_fn.lower(state, batch, idx).compile()
            t_compile = time.perf_counter() - t0
            hlo_text = compiled.as_text()
        t0 = time.perf_counter()
        state, m = compiled(state, batch, idx)
        jax.block_until_ready(m["loss"])
        dt = time.perf_counter() - t0
        if step > 0:          # step 0 is the warmup call
            t_steps += dt
            n_timed += 1
        losses.append(float(m["loss"]))
    TS.check_state_dtypes(state)  # f32 masters under any policy
    # fallback group size for collectives with no parseable replica_groups
    # (both SHARDED_MESH axes have size 2; unused on the 1-device variants)
    cm = HLOCostModel(hlo_text, default_group=2)
    mflops, mbytes, mcoll = cm.totals()
    s_per_step = t_steps / max(n_timed, 1)
    row = {
        "name": name,
        "precision": tc.precision or "f32",
        "impl": tc.impl,
        "loss_impl": tc.loss_impl or "dense",
        "steps_timed": n_timed,
        "steps_per_s": round(1.0 / max(s_per_step, 1e-9), 3),
        "ms_per_step": round(1e3 * s_per_step, 2),
        "compile_s": round(t_compile, 2),
        "loss_first": round(losses[0], 6),
        "loss_final": round(losses[-1], 6),
        "sat_rate": float(m["sat_rate"]),
        "modeled_flops": mflops,
        "modeled_hbm_bytes": mbytes,
        "modeled_collective_bytes": mcoll,
        "modeled_collective_counts": {
            k: int(v) for k, v in sorted(cm.collective_counts().items())},
    }
    return row, state


def bench_variant(name, precision, impl, loss_impl, steps, seed=0):
    tc, loader = _build(precision, impl, loss_impl, steps, seed)
    state = TS.init_train_state(jax.random.PRNGKey(seed), tc)
    row, _ = _time_steps(name, tc, loader, state, steps)
    return row


def bench_sharded_worker(steps, seed=0):
    """Runs inside the 4-forced-host-device subprocess: time the fsdp
    train step on the (data=2, fsdp=2) mesh and report per-device state
    bytes alongside throughput.  Same _build/_time_steps protocol as the
    local variants, plus mesh setup and the byte columns."""
    data_sz, fsdp_sz = SHARDED_MESH
    mesh = SS.make_train_mesh(data_sz, fsdp_sz)
    TS.set_mesh(mesh)
    tc, loader = _build("f32", "chunked", "dense", steps, seed,
                        n_shards=data_sz * fsdp_sz, fsdp=True)
    state = TS.init_train_state(jax.random.PRNGKey(seed), tc)
    state, _shardings = SS.shard_train_state(state, mesh)
    row, state = _time_steps(f"fsdp-d{data_sz}f{fsdp_sz}", tc, loader,
                             state, steps)
    heavy = {"params": state["params"], "m": state["opt"]["m"],
             "v": state["opt"]["v"]}
    row["mesh"] = f"data:{data_sz},fsdp:{fsdp_sz}"
    row["param_bytes_per_device"] = SS.per_device_bytes(heavy)
    row["param_bytes_replicated"] = sum(
        int(np.prod(l.shape)) * 4 for l in jax.tree.leaves(heavy))
    return row


def _sharded_row(steps, seed=0):
    """Spawn the 4-device worker (the main process keeps one device)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.step_bench",
         "--sharded-worker", "--steps", str(steps), "--seed", str(seed)],
        capture_output=True, text=True, env=env, cwd=root, timeout=900)
    for line in p.stdout.splitlines():
        if line.startswith(_ROW_MARK):
            return json.loads(line[len(_ROW_MARK):])
    raise RuntimeError(f"sharded step_bench worker failed "
                       f"(rc={p.returncode}): {p.stderr[-2000:]}")


def collect(steps=12, seed=0):
    rows = []
    for name, precision, impl, loss_impl in VARIANTS:
        rows.append(bench_variant(name, precision, impl, loss_impl,
                                  steps, seed))
    base = rows[0]
    for r in rows:
        r["delta_loss_vs_f32"] = round(
            abs(r["loss_final"] - base["loss_final"]), 6)
        r["speedup_vs_f32"] = round(
            base["ms_per_step"] / max(r["ms_per_step"], 1e-9), 3)
    sharded = _sharded_row(steps, seed)
    # the sharded loader draws per-shard-permuted batches: its loss path
    # is parity-tested bit-exactly elsewhere, not comparable here
    sharded["delta_loss_vs_f32"] = None
    sharded["speedup_vs_f32"] = round(
        base["ms_per_step"] / max(sharded["ms_per_step"], 1e-9), 3)
    rows.append(sharded)
    return rows


def run(steps=None, seed=0):
    """benchmarks.run harness entry: (name, us_per_call, derived) rows."""
    rows = collect(steps=steps or 12, seed=seed)
    return [(f"step_bench/{r['name']}", 1e3 * r["ms_per_step"],
             f"steps_per_s={r['steps_per_s']};"
             f"delta_loss_vs_f32={r['delta_loss_vs_f32']};"
             f"sat_rate={r['sat_rate']}") for r in rows]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="4 timed steps (CI smoke)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_step.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sharded-worker", action="store_true",
                    help=argparse.SUPPRESS)   # internal: 4-device child
    args = ap.parse_args(argv)
    steps = args.steps or (5 if args.quick else 12)

    if args.sharded_worker:
        row = bench_sharded_worker(steps, seed=args.seed)
        print(_ROW_MARK + json.dumps(row))
        return row

    rows = collect(steps=steps, seed=args.seed)
    doc = {
        "bench": "step_bench",
        "arch": "clip-vitb32-cc12m (reduced)",
        "global_batch": GLOBAL_BATCH,
        "backend": jax.default_backend(),
        "interpret_kernels": jax.default_backend() != "tpu",
        "steps": steps,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    for r in rows:
        print(f"{r['name']:>11}: {r['ms_per_step']:8.1f} ms/step "
              f"({r['steps_per_s']:.2f} steps/s)  "
              f"dloss_vs_f32={r['delta_loss_vs_f32']}")
    print(f"wrote {args.out}")
    return doc


if __name__ == "__main__":
    main()
