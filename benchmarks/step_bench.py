"""End-to-end train-step throughput: the f32 dense baseline vs the bf16
flash+fused fast path.

Times full optimizer steps (towers fwd/bwd + FCCO loss + AdamW update,
state donated) of the reduced ViT-B/32-family CLIP on synthetic data and
emits ``BENCH_step.json`` with one row per variant:

    f32-dense   : precision=f32,  impl=chunked, loss_impl=dense
    bf16-flash  : precision=bf16, impl=flash,   loss_impl=fused

On CPU the Pallas kernels run in interpret mode, so absolute times measure
the correctness surface, not TPU speed — the row schema and the loss-parity
column are the durable part (the ``delta_loss_vs_f32`` field bounds the
bf16 policy drift after ``steps`` real optimizer steps).

Run: PYTHONPATH=src python -m benchmarks.step_bench [--quick] [--steps N]
     [--out BENCH_step.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import fastclip as FC
from repro.core import train_step as TS
from repro.core.schedules import lr_warmup_cosine
from repro.data import ContrastiveDataset, ShardedLoader
from repro.launch.steps import donated_jit
from repro.optim import adamw

N_SAMPLES = 256
GLOBAL_BATCH = 64

VARIANTS = [
    # (name, precision, attention impl, loss impl)
    ("f32-dense", "f32", "chunked", "dense"),
    ("bf16-flash", "bf16", "flash", "fused"),
]


def _build(precision, impl, loss_impl, steps, seed=0):
    cfg = get_arch("clip-vitb32-cc12m").reduced()
    ds = ContrastiveDataset(n=N_SAMPLES, image_size=cfg.clip.image_size,
                            context_length=cfg.clip.context_length,
                            vocab_size=cfg.vocab_size, n_classes=32,
                            seed=seed)
    loader = ShardedLoader(ds, global_batch=GLOBAL_BATCH, seed=seed)
    fc = FC.FastCLIPConfig(version="v3", n_samples=N_SAMPLES,
                           steps_per_epoch=loader.steps_per_epoch,
                           gamma_decay_epochs=2)
    tc = TS.TrainStepConfig(arch=cfg, fc=fc, optimizer=adamw(),
                            lr_fn=lr_warmup_cosine(1e-3, 4, max(steps, 8)),
                            wd=0.1, impl=impl, loss_impl=loss_impl,
                            precision=precision)
    return tc, loader


def bench_variant(name, precision, impl, loss_impl, steps, seed=0):
    tc, loader = _build(precision, impl, loss_impl, steps, seed)
    state = TS.init_train_state(jax.random.PRNGKey(seed), tc)
    step_fn = donated_jit(TS.make_train_step(tc))

    t_compile = t_steps = 0.0
    n_timed = 0
    losses = []
    for epoch, step, idx, batch in loader.steps(steps):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        state, m = step_fn(state, batch, jnp.asarray(idx))
        jax.block_until_ready(m["loss"])
        dt = time.perf_counter() - t0
        if step == 0:
            t_compile = dt
        else:
            t_steps += dt
            n_timed += 1
        losses.append(float(m["loss"]))
    TS.check_state_dtypes(state)  # f32 masters under any policy
    s_per_step = t_steps / max(n_timed, 1)
    return {
        "name": name,
        "precision": precision,
        "impl": impl,
        "loss_impl": loss_impl,
        "steps_timed": n_timed,
        "steps_per_s": round(1.0 / max(s_per_step, 1e-9), 3),
        "ms_per_step": round(1e3 * s_per_step, 2),
        "compile_s": round(t_compile, 2),
        "loss_first": round(losses[0], 6),
        "loss_final": round(losses[-1], 6),
        "sat_rate": float(m["sat_rate"]),
    }


def collect(steps=12, seed=0):
    rows = []
    for name, precision, impl, loss_impl in VARIANTS:
        rows.append(bench_variant(name, precision, impl, loss_impl,
                                  steps, seed))
    base = rows[0]
    for r in rows:
        r["delta_loss_vs_f32"] = round(
            abs(r["loss_final"] - base["loss_final"]), 6)
        r["speedup_vs_f32"] = round(
            base["ms_per_step"] / max(r["ms_per_step"], 1e-9), 3)
    return rows


def run(steps=None, seed=0):
    """benchmarks.run harness entry: (name, us_per_call, derived) rows."""
    rows = collect(steps=steps or 12, seed=seed)
    return [(f"step_bench/{r['name']}", 1e3 * r["ms_per_step"],
             f"steps_per_s={r['steps_per_s']};"
             f"delta_loss_vs_f32={r['delta_loss_vs_f32']};"
             f"sat_rate={r['sat_rate']}") for r in rows]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="4 timed steps (CI smoke)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_step.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    steps = args.steps or (5 if args.quick else 12)

    rows = collect(steps=steps, seed=args.seed)
    doc = {
        "bench": "step_bench",
        "arch": "clip-vitb32-cc12m (reduced)",
        "global_batch": GLOBAL_BATCH,
        "backend": jax.default_backend(),
        "interpret_kernels": jax.default_backend() != "tpu",
        "steps": steps,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    for r in rows:
        print(f"{r['name']:>11}: {r['ms_per_step']:8.1f} ms/step "
              f"({r['steps_per_s']:.2f} steps/s)  "
              f"dloss_vs_f32={r['delta_loss_vs_f32']}")
    print(f"wrote {args.out}")
    return doc


if __name__ == "__main__":
    main()
