"""Shared micro-scale training harness for the paper-table benchmarks.

The paper's Tables 3-5 compare algorithm variants by downstream accuracy
after full training runs; the CPU-container analog trains the reduced
ViT-B/32-family CLIP on synthetic class-structured data and reports
retrieval accuracy on held-out pairs + per-step wall time.  Relative
orderings (cosine gamma > constant, v3 strong, AdamW best) are the claims
under test; see EXPERIMENTS.md §Claims.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import fastclip as FC
from repro.core import train_step as TS
from repro.core.schedules import lr_warmup_cosine
from repro.data import ContrastiveDataset, ShardedLoader
from repro.optim import get_optimizer

N_SAMPLES = 1024
GLOBAL_BATCH = 128
N_CLASSES = 256
EVAL_BATCH = 256


def build(version="v3", optimizer="adamw", lr=2e-3, gamma=0.6,
          gamma_min=0.2, steps=120, seed=0, rho=6.5, n=N_SAMPLES,
          wd=0.1, gamma_schedule="auto"):
    cfg = get_arch("clip-vitb32-cc12m").reduced()
    ds = ContrastiveDataset(n=n, image_size=cfg.clip.image_size,
                            context_length=cfg.clip.context_length,
                            vocab_size=cfg.vocab_size, n_classes=N_CLASSES,
                            noise=0.5, seed=seed)
    loader = ShardedLoader(ds, global_batch=GLOBAL_BATCH, seed=seed)
    fc = FC.FastCLIPConfig(
        version=version, n_samples=n, rho=rho, gamma=gamma,
        gamma_min=gamma_min, gamma_schedule=gamma_schedule,
        tau_init=0.07 if version == "v3" else 0.03,
        lr_tau=2e-4 if version == "v3" else 1e-2,
        steps_per_epoch=loader.steps_per_epoch,
        gamma_decay_epochs=max(1, steps // (2 * loader.steps_per_epoch)))
    tc = TS.TrainStepConfig(
        arch=cfg, fc=fc, optimizer=get_optimizer(optimizer),
        lr_fn=lr_warmup_cosine(lr, 8, steps), wd=wd)
    return cfg, ds, loader, tc


def train_and_eval(version="v3", optimizer="adamw", steps=120, seed=0,
                   **kw):
    cfg, ds, loader, tc = build(version=version, optimizer=optimizer,
                                steps=steps, seed=seed, **kw)
    state = TS.init_train_state(jax.random.PRNGKey(seed), tc)
    step_fn = jax.jit(TS.make_train_step(tc))
    eval_idx = np.arange(EVAL_BATCH)
    eval_batch = {k: jnp.asarray(v) for k, v in ds.batch(eval_idx).items()}

    def evaluate(st):
        return float(TS.retrieval_accuracy(st["params"], cfg, eval_batch,
                                           classes=ds.classes[eval_idx]))

    t_total, n_timed = 0.0, 0
    every = max(steps // 10, 1)
    curve = []
    for epoch, step, idx, batch in loader.steps(steps):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        state, m = step_fn(state, batch, jnp.asarray(idx))
        jax.block_until_ready(m["loss"])
        if step > 2:                      # skip compile steps
            t_total += time.perf_counter() - t0
            n_timed += 1
        if (step + 1) % every == 0:       # accuracy curve (paper Fig. 1)
            curve.append(evaluate(state))
    return {
        "acc": curve[-1],
        "auc": float(np.mean(curve)),     # convergence-speed summary
        "curve": [round(c, 4) for c in curve],
        "loss": float(m["loss"]),
        "tau": float(m["tau"]),
        "us_per_step": 1e6 * t_total / max(n_timed, 1),
    }
