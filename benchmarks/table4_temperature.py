"""Paper Table 4: the four temperature-update rules (FastCLIP-v0..v3).
Claim under test: v3 (RGCL-g, global learnable tau) is the strongest
overall; all four are close at small scale."""
from benchmarks.common import train_and_eval


def run(steps=120, seed=0):
    rows = []
    for v in ("v0", "v1", "v2", "v3"):
        r = train_and_eval(v, steps=steps, seed=seed)
        rows.append((f"table4/fastclip-{v}", r["us_per_step"],
                     f"acc={r['acc']:.4f};tau={r['tau']:.4f}"))
    return rows
