"""Paper Table 3: constant vs cosine inner-LR (gamma) schedule.

Three pairs, each differing ONLY in the gamma schedule:
    SogCLR        vs FastCLIP-v1
    iSogCLR       vs FastCLIP-v2
    v3 (Const.)   vs FastCLIP-v3
Claim under test: cosine gamma beats constant gamma on each pair.
"""
from benchmarks.common import train_and_eval

PAIRS = [("sogclr", "v1"), ("isogclr", "v2"), ("v3", "v3")]


def run(steps=120, seed=0):
    rows = []
    for const_v, cos_v in PAIRS:
        r_const = train_and_eval(const_v, steps=steps, seed=seed, gamma=0.6,
                                 gamma_schedule="constant")
        r_cos = train_and_eval(cos_v, steps=steps, seed=seed, gamma_min=0.2,
                               gamma_schedule="cosine")
        tag = "v3(Const)" if const_v == cos_v else const_v
        rows.append((f"table3/{tag}", r_const["us_per_step"],
                     f"acc={r_const['acc']:.4f}"))
        rows.append((f"table3/{cos_v}(cosine)", r_cos["us_per_step"],
                     f"acc={r_cos['acc']:.4f};improvement="
                     f"{r_cos['acc'] - r_const['acc']:+.4f}"))
    return rows
