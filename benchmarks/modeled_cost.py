"""Modeled-cost regression gate: run ``HLOCostModel`` over the lowered
production modules and compare against checked-in goldens.

Modules covered (all on the reduced ViT-B/32-family CLIP):

    step-dense   : f32 / chunked-attention / dense-loss train step
    step-fused   : bf16 / flash-attention / fused-Pallas-loss train step
    eval-extract : ``eval.extraction.make_extract_fn`` tower-pair forward
    serve-encode : ``eval.extraction.make_serve_encode_fn`` image encode
                   at the serving engine's max batch bucket
    step-fsdp    : the train step on a (data=2, fsdp=2) mesh — runs in a
                   subprocess with 4 forced host devices; its collective
                   counts are the PR 5 sharding contract (reduce-scatters
                   present, bounded all-reduces) expressed as numbers
    step-fsdp-microbatch : the same step with the PR 10 comm/compute-
                   overlap pipeline (TrainStepConfig.microbatch=2); the
                   extra per-micro-step reduce-scatters and the
                   still-bounded all-reduces are the overlap contract
                   expressed as numbers

Per module the row records modeled flops, HBM bytes, collective bytes and
per-kind collective counts — machine-independent properties of the lowered
HLO, so they regress meaningfully on CPU CI.  ``--write-golden`` snapshots
``benchmarks/goldens/modeled_cost.json``; ``--check`` (the CI mode,
perf-model-smoke job) fails when collective counts differ at all or when
flops/bytes drift beyond ``--rel-tol`` (default 5%).  ``BENCH_step.json``
rows (``benchmarks/step_bench.py``) carry the same columns per timed
variant.

Usage:
    PYTHONPATH=src python -m benchmarks.modeled_cost --check
    PYTHONPATH=src python -m benchmarks.modeled_cost --write-golden
        [--skip-fsdp] [--golden PATH] [--rel-tol 0.05]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

REL_TOL = 0.05
GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "goldens", "modeled_cost.json")
_ROW_MARK = "FSDP-MODELED-ROW "


def _model_row(module, hlo_text, default_group=2):
    from repro.roofline.hlo_cost import HLOCostModel
    cm = HLOCostModel(hlo_text, default_group=default_group)
    flops, hbm, coll = cm.totals()
    return {
        "module": module,
        "modeled_flops": flops,
        "modeled_hbm_bytes": hbm,
        "modeled_collective_bytes": coll,
        "collective_counts": {
            k: int(v) for k, v in sorted(cm.collective_counts().items())},
    }


def _step_row(module, precision, impl, loss_impl):
    """Lower the train step with abstract state/batch (no init compute)."""
    from benchmarks.step_bench import GLOBAL_BATCH, _build
    from repro.core import train_step as TS
    from repro.launch.steps import donated_jit
    tc, _ = _build(precision, impl, loss_impl, steps=8)
    c = tc.arch.clip
    state = jax.eval_shape(lambda k: TS.init_train_state(k, tc),
                           jax.random.PRNGKey(0))
    batch = {
        "images": jax.ShapeDtypeStruct(
            (GLOBAL_BATCH, c.image_size, c.image_size, 3), jnp.float32),
        "texts": jax.ShapeDtypeStruct(
            (GLOBAL_BATCH, c.context_length), jnp.int32),
    }
    idx = jax.ShapeDtypeStruct((GLOBAL_BATCH,), jnp.int32)
    compiled = donated_jit(TS.make_train_step(tc)).lower(
        state, batch, idx).compile()
    return _model_row(module, compiled.as_text())


def _eval_extract_row(batch_size=64):
    from repro.configs import get_arch
    from repro.eval import extraction as EX
    from repro.models import backbones as BB
    cfg = get_arch("clip-vitb32-cc12m").reduced()
    c = cfg.clip
    params = jax.eval_shape(lambda k: BB.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    batch = {
        "images": jax.ShapeDtypeStruct(
            (batch_size, c.image_size, c.image_size, 3), jnp.float32),
        "texts": jax.ShapeDtypeStruct(
            (batch_size, c.context_length), jnp.int32),
    }
    jfn = EX.make_extract_fn(lambda p, b: BB.encode_pair(p, cfg, b))
    compiled = jfn.lower(params, batch).compile()
    return _model_row("eval-extract", compiled.as_text())


def _serve_encode_row(max_batch=8):
    from repro.configs import get_arch
    from repro.eval import extraction as EX
    from repro.models import backbones as BB, clip as CL
    cfg = get_arch("clip-vitb32-cc12m").reduced()
    c = cfg.clip
    params = jax.eval_shape(lambda k: BB.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    images = jax.ShapeDtypeStruct(
        (max_batch, c.image_size, c.image_size, 3), jnp.float32)
    jfn = EX.make_serve_encode_fn(
        lambda p, imgs: CL.encode_image(p, cfg, imgs))
    compiled = jfn.lower(params, images).compile()
    return _model_row("serve-encode", compiled.as_text())


def fsdp_worker():
    """Runs in the 4-forced-host-device subprocess (see ``_fsdp_rows``):
    shard the train state on the (data=2, fsdp=2) mesh, lower the step
    unpipelined and with microbatch=2, model both HLOs, print the rows."""
    import dataclasses

    from benchmarks.step_bench import SHARDED_MESH, _build
    from repro.core import shard_state as SS
    from repro.core import train_step as TS
    from repro.launch.steps import donated_jit
    data_sz, fsdp_sz = SHARDED_MESH
    mesh = SS.make_train_mesh(data_sz, fsdp_sz)
    TS.set_mesh(mesh)
    tc, loader = _build("f32", "chunked", "dense", steps=8,
                        n_shards=data_sz * fsdp_sz, fsdp=True)
    state = TS.init_train_state(jax.random.PRNGKey(0), tc)
    state, _ = SS.shard_train_state(state, mesh)
    _, _, idx, batch = next(iter(loader.steps(1)))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    idx = jnp.asarray(idx)
    for module, cfg in (
            (f"step-fsdp-d{data_sz}f{fsdp_sz}", tc),
            ("step-fsdp-microbatch", dataclasses.replace(tc, microbatch=2))):
        compiled = donated_jit(TS.make_train_step(cfg)).lower(
            state, batch, idx).compile()
        row = _model_row(module, compiled.as_text(), default_group=fsdp_sz)
        print(_ROW_MARK + json.dumps(row))


def _fsdp_rows():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.modeled_cost", "--fsdp-worker"],
        capture_output=True, text=True, env=env, cwd=root, timeout=900)
    rows = [json.loads(line[len(_ROW_MARK):])
            for line in p.stdout.splitlines() if line.startswith(_ROW_MARK)]
    if not rows:
        raise RuntimeError(f"fsdp modeled-cost worker failed "
                           f"(rc={p.returncode}): {p.stderr[-2000:]}")
    return rows


def collect(skip_fsdp=False):
    rows = [
        _step_row("step-dense", "f32", "chunked", "dense"),
        _step_row("step-fused", "bf16", "flash", "fused"),
        _eval_extract_row(),
        _serve_encode_row(),
    ]
    if not skip_fsdp:
        rows.extend(_fsdp_rows())
    return rows


def compare(rows, golden, rel_tol=REL_TOL):
    """Drift report: [] when everything matches.  Collective counts must
    match EXACTLY (a changed count is a changed communication pattern);
    flops/bytes may drift up to rel_tol (minor fusion-shape churn)."""
    gold = {r["module"]: r for r in golden["rows"]}
    problems = []
    for row in rows:
        g = gold.get(row["module"])
        if g is None:
            problems.append(f"{row['module']}: no golden entry "
                            f"(run --write-golden)")
            continue
        if row["collective_counts"] != g["collective_counts"]:
            problems.append(
                f"{row['module']}: collective counts "
                f"{row['collective_counts']} != golden "
                f"{g['collective_counts']}")
        for key in ("modeled_flops", "modeled_hbm_bytes",
                    "modeled_collective_bytes"):
            cur, ref = float(row[key]), float(g[key])
            if ref == 0.0:
                drift = 0.0 if cur == 0.0 else float("inf")
            else:
                drift = abs(cur - ref) / ref
            if drift > rel_tol:
                problems.append(f"{row['module']}: {key} {cur:.4g} vs "
                                f"golden {ref:.4g} ({100 * drift:.1f}% "
                                f"> {100 * rel_tol:.0f}%)")
    missing = set(gold) - {r["module"] for r in rows}
    for m in sorted(missing):
        problems.append(f"{m}: in golden but not produced this run")
    return problems


def run(steps=None, seed=None):
    """benchmarks.run harness entry (no golden gate, just the rows)."""
    return [(f"modeled_cost/{r['module']}", 0.0,
             f"flops={r['modeled_flops']:.3e};"
             f"hbm_bytes={r['modeled_hbm_bytes']:.3e};"
             f"coll={r['collective_counts']}") for r in collect()]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="compare against the golden; exit 1 on drift")
    ap.add_argument("--write-golden", action="store_true")
    ap.add_argument("--golden", default=GOLDEN_PATH)
    ap.add_argument("--rel-tol", type=float, default=REL_TOL)
    ap.add_argument("--skip-fsdp", action="store_true",
                    help="skip the 4-device subprocess row")
    ap.add_argument("--fsdp-worker", action="store_true",
                    help=argparse.SUPPRESS)   # internal: 4-device child
    args = ap.parse_args()

    if args.fsdp_worker:
        fsdp_worker()
        return

    rows = collect(skip_fsdp=args.skip_fsdp)
    for r in rows:
        print(f"{r['module']:>16}: flops={r['modeled_flops']:.3e} "
              f"hbm={r['modeled_hbm_bytes']:.3e} "
              f"coll_bytes={r['modeled_collective_bytes']:.3e} "
              f"counts={r['collective_counts']}")

    if args.write_golden:
        os.makedirs(os.path.dirname(args.golden), exist_ok=True)
        doc = {"bench": "modeled_cost",
               "arch": "clip-vitb32-cc12m (reduced)",
               "rel_tol": args.rel_tol, "rows": rows}
        with open(args.golden, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.golden}")
        return

    if args.check:
        try:
            with open(args.golden) as f:
                golden = json.load(f)
        except OSError:
            print(f"FAIL: golden {args.golden} missing — run "
                  f"--write-golden first", file=sys.stderr)
            sys.exit(1)
        if args.skip_fsdp:
            golden = dict(golden)
            golden["rows"] = [r for r in golden["rows"]
                              if not r["module"].startswith("step-fsdp")]
        problems = compare(rows, golden, rel_tol=args.rel_tol)
        if problems:
            print("FAIL: modeled-cost drift vs golden:", file=sys.stderr)
            for p in problems:
                print(f"  {p}", file=sys.stderr)
            sys.exit(1)
        print(f"OK: {len(rows)} modules within tolerance "
              f"(counts exact, flops/bytes <= {100 * args.rel_tol:.0f}%)")


if __name__ == "__main__":
    main()
