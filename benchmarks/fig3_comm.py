"""Paper Fig. 3 / §4 claim: the FastCLIP gradient reduction moves fewer
bytes than the OpenCLIP-style (DDP) reduction, and the gap grows with
worker count.  Dry-run analog: collective bytes from the lowered HLO at
K = 4, 8 workers (subprocess with forced host devices) plus the 256-chip
numbers from experiments/dryrun if present."""
import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os, sys, json
    K = int(sys.argv[1])
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={K}"
    sys.path.insert(0, os.path.join(sys.argv[2], "src"))
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import distributed as D, losses as LS
    from repro.roofline.analysis import collective_stats
    mesh = Mesh(np.array(jax.devices()).reshape(K), ("data",))
    b, dim = 128, 512
    B = b * K
    fcco_op = D.make_fcco_loss_op(("data",), 1e-14, True,
                                  loss_impl="dense")
    def make(red):
        def inner(e1l, e2l, u1l, u2l):
            sg = jax.lax.stop_gradient
            e1n, e2n = LS.l2_normalize(e1l), LS.l2_normalize(e2l)
            if red == "fastclip":   # production engine: no stats pre-pass
                loss, _ = fcco_op(e1n, e2n, u1l, u2l, 0.07, 0.07, 0.5)
                return loss
            off = jax.lax.axis_index("data") * e1l.shape[0]
            e1a = jax.lax.all_gather(sg(e1n), "data", tiled=True)
            e2a = jax.lax.all_gather(sg(e2n), "data", tiled=True)
            st = LS.row_stats(sg(e1n), sg(e2n), e1a, e2a, 0.07, 0.07,
                              row_offset=off)
            lg1, lg2 = LS.log_g(st)
            lw1, lw2 = LS.fcco_log_weights(
                LS.update_log_u(u1l, lg1, .5),
                LS.update_log_u(u2l, lg2, .5), 0.07, 0.07, 1e-14)
            f = D.make_allgather_ad_pair_loss(("data",))
            loss, _ = f(e1n, e2n, lw1, lw2, 0.07, 0.07)
            return loss
        def outer(e1, e2, u1, u2):
            return D.shard_map(inner, mesh=mesh,
                                 in_specs=(P("data"),)*4,
                                 out_specs=P())(e1, e2, u1, u2)
        return lambda e1, e2, u1, u2: jax.grad(
            lambda a, c: outer(a, c, u1, u2), argnums=(0, 1))(e1, e2)
    args = ((jax.ShapeDtypeStruct((B, dim), jnp.float32),)*2
            + (jax.ShapeDtypeStruct((B,), jnp.float32),)*2)
    out = {}
    for red in ("fastclip", "allgather_ad"):
        comp = jax.jit(make(red)).lower(*args).compile()
        cs = collective_stats(comp.as_text(), default_group=K)
        out[red] = {"bytes": cs.total_bytes, "counts": cs.counts}
    print(json.dumps(out))
""")


def run(steps=None, seed=None):
    rows = []
    for K in (4, 8):
        p = subprocess.run([sys.executable, "-c", _SCRIPT, str(K), ROOT],
                           capture_output=True, text=True, timeout=300)
        if p.returncode != 0:
            rows.append((f"fig3/K={K}", 0.0, "FAILED"))
            continue
        out = json.loads(p.stdout.strip().splitlines()[-1])
        fb = out["fastclip"]["bytes"]
        ob = out["allgather_ad"]["bytes"]
        rows.append((f"fig3/K={K}/fastclip", 0.0, f"coll_bytes={fb}"))
        rows.append((f"fig3/K={K}/openclip-style", 0.0,
                     f"coll_bytes={ob};reduction={100*(1-fb/ob):.1f}%"))
    # 256-chip numbers from the dry-run sweep, if available
    for red in ("fastclip", "allgather_ad"):
        fp = os.path.join(ROOT, "experiments", "dryrun",
                          f"qwen3-1.7b__train_4k__16x16__contrastive__{red}"
                          ".json")
        if os.path.exists(fp):
            d = json.load(open(fp))
            rows.append((f"fig3/256chips/{red}", 0.0,
                         f"coll_bytes_per_dev="
                         f"{d['collective_bytes_per_device']:.3e}"))
    return rows
