"""Paper Fig. 3/4 + Tables 15-16 analog: per-iteration time model across
node counts, from measured HLO collective bytes + the roofline constants.

t_iter(K) = max(compute_term, memory_term) + collective_term(K)

compute/memory are per-device and K-independent (fixed per-GPU batch, as
in the paper); the collective term scales with the gathered global batch
K*b*d.  Reports the modeled FastCLIP-vs-OpenCLIP gap vs K — the dry-run
analog of the paper's observation that FastCLIP wins at 4-8 nodes.
"""
from repro.roofline.analysis import ICI_BW

# measured per-loss-call collective bytes at K workers (from fig3_comm at
# K=8, b=128, d=512, f32): forward gathers 2*K*b*d*4 bytes; OpenCLIP adds
# the backward feature-grad reduce-scatter of the same size; FastCLIP adds
# only O(K*b) scalars.
B_LOCAL = 128
DIM = 512


def loss_comm_bytes(K, reduction):
    feat = 2 * K * B_LOCAL * DIM * 4 * (K - 1) / K      # fwd all-gathers
    if reduction == "fastclip":
        scal = 5 * K * B_LOCAL * 4 * (K - 1) / K        # s_ii, w1, w2, taus
        return feat + scal
    return 2 * feat                                      # + bwd RS


def run(steps=None, seed=None):
    rows = []
    # per-device compute time of the towers is K-independent; use the
    # medium-setting estimate: ViT-B/32 fwd+bwd ~ 3*2*88e6*(49+77 tokens)
    tower_s = 3 * 2 * 88e6 * 126 * B_LOCAL / 197e12
    for K in (4, 8, 16, 32):
        t_fc = tower_s + loss_comm_bytes(K, "fastclip") / ICI_BW
        t_oc = tower_s + loss_comm_bytes(K, "allgather_ad") / ICI_BW
        rows.append((f"scaling/K={K}", t_fc * 1e6,
                     f"fastclip_s={t_fc:.5f};openclip_s={t_oc:.5f};"
                     f"speedup={t_oc / t_fc:.3f}x"))
    return rows
