"""Serving-engine offered-load sweep (PR 8): latency / shed / cache.

Drives the ``repro.serve`` engine (planted image tower, CPU-friendly)
with an open-loop Poisson-ish arrival process at multiples of its
measured capacity and reports, per offered load:

  * p50 / p99 completed-request latency (ms),
  * shed rate (typed rejections / offered) and its split
    (OVERLOADED at admission vs DEADLINE),
  * cache hit rate (the payload pool is smaller than the request
    count, so steady-state traffic exercises the content-hash cache).

The shape to expect: below capacity the queue stays short and p99
tracks the micro-batch time; past capacity the bounded queue converts
the excess into admission-time shed instead of unbounded latency —
goodput (completed/s) holds instead of collapsing, which is the whole
point of admission control.

Emits ``BENCH_serve.json`` and the harness CSV rows.

Run: PYTHONPATH=src python -m benchmarks.serve_bench [--quick]
"""
import argparse
import json
import time

import numpy as np


def _warm(srv, pays):
    """Compile every bucket shape before timing anything (direct calls
    into the engine's jitted compute — deterministic, unlike hoping a
    burst forms full batches)."""
    params, _step = srv.store.snapshot()
    for n in srv.compute.buckets:
        srv.compute(params, [pays[i % len(pays)] for i in range(n)])


def _measure_capacity(srv, make_unique, warm=8):
    """Requests/s the batcher sustains on full batches of *uncached*
    payloads (solo run) — the compute-path capacity."""
    t0 = time.perf_counter()
    futs = [srv.submit(make_unique()) for _ in range(warm * 8)]
    for f in futs:
        f.result(timeout=60.0)
    dt = time.perf_counter() - t0
    return warm * 8 / dt


def run(duration: float = 2.0, quick: bool = False):
    from repro.data import ZeroShotEvalDataset
    from repro.eval import planted as PL
    from repro.serve import (EmbedServer, ServeConfig, ServeRejection)

    if quick:
        duration = 0.5
    ds = ZeroShotEvalDataset(n_classes=8, n_per_class=2, seed=0)
    params = PL.planted_params(ds)

    def encode(params, batch):
        return PL.encode_image(params, batch["images"])

    # hot set: distinct-class images (in-class images are bitwise
    # equal) — repeated requests for these exercise the cache.  Unique
    # payloads (a fresh scale per request -> fresh content hash) force
    # the compute path; real traffic is a mix of both.
    hot = [{"images": np.asarray(ds.images(np.array([c * 2])))[0]}
           for c in range(ds.n_classes)]
    counter = [0]

    def make_unique():
        counter[0] += 1
        base = hot[counter[0] % len(hot)]["images"]
        return {"images": base * np.float32(1.0 + 1e-4 * counter[0])}

    cal = EmbedServer(encode, params, 0, ServeConfig(max_batch=8, seed=0))
    _warm(cal, hot)
    capacity = _measure_capacity(cal, make_unique)
    cal.close()

    rows, results = [], []
    for mult in (0.5, 1.0, 2.0):
        srv = EmbedServer(encode, params, 0, ServeConfig(
            max_batch=8, queue_capacity=32, seed=0))
        _warm(srv, hot)                     # compile all buckets first
        rate = capacity * mult
        deadline = 0.25
        interval = 1.0 / rate
        offered = completed = 0
        shed = {"OVERLOADED": 0, "DEADLINE": 0, "UNAVAILABLE": 0}
        lat, futs = [], []
        t_end = time.perf_counter() + duration
        next_t = time.perf_counter()
        rng = np.random.default_rng(0)
        while time.perf_counter() < t_end:
            now = time.perf_counter()
            if now < next_t:
                time.sleep(next_t - now)
            next_t += interval
            offered += 1
            # 25% hot traffic (cache-eligible), 75% unique (compute)
            pay = (hot[int(rng.integers(len(hot)))]
                   if rng.random() < 0.25 else make_unique())
            try:
                futs.append(srv.submit(pay, deadline=deadline))
            except ServeRejection as e:
                shed[e.code] += 1
        t_drain0 = time.perf_counter()
        for f in futs:
            try:
                r = f.result(timeout=60.0)
                completed += 1
                lat.append(r.latency)
            except ServeRejection as e:
                shed[e.code] += 1
        drain = time.perf_counter() - t_drain0
        st = srv.snapshot_stats()
        srv.close()
        p50 = float(np.percentile(lat, 50)) * 1e3 if lat else 0.0
        p99 = float(np.percentile(lat, 99)) * 1e3 if lat else 0.0
        n_shed = sum(shed.values())
        hit_rate = (st["cache_hits"]
                    / max(1, st["cache_hits"] + st["cache_misses"]))
        row = {"offered_x_capacity": mult, "offered_rate_rps": rate,
               "offered": offered, "completed": completed,
               "p50_ms": round(p50, 3), "p99_ms": round(p99, 3),
               "shed_rate": round(n_shed / max(1, offered), 4),
               "shed": shed, "cache_hit_rate": round(hit_rate, 4),
               "goodput_rps": round(completed / duration, 1),
               "drain_s": round(drain, 3)}
        results.append(row)
        rows.append((f"serve_load_{mult}x",
                     p99 * 1e3,   # us_per_call column = p99 in us
                     f"p50={p50:.1f}ms shed={row['shed_rate']:.0%} "
                     f"hit={hit_rate:.0%} goodput={row['goodput_rps']}rps"))
    doc = {"bench": "serve_bench", "capacity_rps": round(capacity, 1),
           "duration_s": duration, "deadline_ms": 250,
           "max_batch": 8, "queue_capacity": 32, "rows": results}
    with open("BENCH_serve.json", "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--duration", type=float, default=2.0)
    args = ap.parse_args()
    for name, us, derived in run(duration=args.duration, quick=args.quick):
        print(f"{name},{us:.1f},{derived}")
    print("wrote BENCH_serve.json")


if __name__ == "__main__":
    main()
