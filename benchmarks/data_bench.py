"""Host-side data-pipeline throughput: streaming vs in-memory (PR 7).

Drains the loader's host stream (no device work) and reports
microseconds per step and samples/s for:

  * the in-memory ``ShardedLoader`` over a synthetic
    ``ContrastiveDataset`` (the oracle path — samples regenerated from
    prototypes per batch),
  * the ``StreamingLoader`` over a materialized shard directory at
    worker counts 1 and 4 (decode + per-sample Philox augment on the
    fly, ``decode_ahead`` pipelining).

The streams are bit-identical by contract (tests/test_streaming.py);
this table is the *cost* of that contract at each batch-assembly
strategy.

Run: PYTHONPATH=src python -m benchmarks.data_bench
"""
import tempfile
import time


def _drain(loader, steps):
    t0 = time.perf_counter()
    n = 0
    for _epoch, _step, idx, _batch in loader.steps(steps):
        n += len(idx)
    dt = time.perf_counter() - t0
    return dt / steps * 1e6, n / dt


def run(steps: int = 32, n: int = 512, global_batch: int = 64):
    from repro.configs import get_arch
    from repro.data import (ContrastiveDataset, ShardedLoader,
                            StreamingLoader, write_contrastive_shards)
    from repro.data.streaming import StreamingDataset

    cfg = get_arch("clip-vitb32-cc12m").reduced()
    ds = ContrastiveDataset(n=n, image_size=cfg.clip.image_size,
                            context_length=cfg.clip.context_length,
                            vocab_size=cfg.vocab_size, n_classes=64)
    rows = []
    with tempfile.TemporaryDirectory() as root:
        write_contrastive_shards(ds, root, samples_per_shard=128)
        configs = [
            ("data_inmemory", ShardedLoader(
                ds, global_batch=global_batch, n_shards=1, seed=0)),
            ("data_stream_w1", StreamingLoader(
                StreamingDataset(root), global_batch=global_batch,
                n_shards=1, seed=0, workers=1, decode_ahead=2)),
            ("data_stream_w4", StreamingLoader(
                StreamingDataset(root), global_batch=global_batch,
                n_shards=1, seed=0, workers=4, decode_ahead=4)),
        ]
        for name, loader in configs:
            _drain(loader, 4)                      # warm page cache / jit
            us, sps = _drain(loader, steps)
            rows.append((name, us, f"samples_per_s={sps:.0f}"))
            if isinstance(loader.dataset, StreamingDataset):
                loader.dataset.close()
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
