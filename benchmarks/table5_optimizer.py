"""Paper Table 5: optimizer comparison on FastCLIP-v3 (AdamW / LAMB /
Lion / SGDM).  Claim under test: AdamW best on most metrics.
Learning rates follow the paper's tuned ratios (App. B Table 10)."""
from benchmarks.common import train_and_eval

# paper-tuned lr/wd ratios, scaled to the micro setting
SETTINGS = {
    "adamw": dict(lr=2e-3, wd=0.1),
    "lamb": dict(lr=4e-3, wd=0.1),
    "lion": dict(lr=4e-4, wd=0.3),
    "sgdm": dict(lr=2.0, wd=3e-6),
}


def run(steps=120, seed=0):
    rows = []
    for opt, kw in SETTINGS.items():
        r = train_and_eval("v3", optimizer=opt, steps=steps, seed=seed, **kw)
        rows.append((f"table5/{opt}", r["us_per_step"],
                     f"acc={r['acc']:.4f};loss={r['loss']:.4f}"))
    return rows
