"""Kernel autotune sweep: candidate tile/chunk configs for the Pallas
kernels, parity-gated, timed, persisted to the tuning table the kernels
consult at call time (``repro.kernels.autotune``).

Sweeps:
    gcl_stats / gcl_grads : (br, bc, d_block) over the loss-engine shapes
    flash_mha             : (q_chunk, kv_chunk) — the chunked-forward /
                            remat-backward block sizes (the Pallas forward
                            itself is fixed at BQ/BK)

Every candidate must pass BOTH parity gates against the dense oracle
(``repro.kernels.ref`` / ``naive_attention``) before it may be timed or
recorded:

    bitwise  on the planted exact-arithmetic case (see
             autotune.planted_gcl_case / planted_attention_case — equality
             is a theorem there, so any mismatch is a real
             indexing/masking bug in that config), and
    1e-5 max-abs on a random-input case (rounding-order differences only).

Off-TPU the kernels run in Pallas interpret mode: the sweep is then a
correctness/compile surface and the timings are NOT TPU-predictive — the
table entries are keyed by backend (``cpu-interpret`` vs ``tpu``), so a
CPU-tuned table never influences TPU runs.  On a real TPU the same sweep
times compiled kernels and the recorded winners are meaningful.

A parity failure makes ``main`` exit nonzero (CI gate); via ``run()`` the
failing candidate becomes an ERROR row and is excluded from the table.

Usage:
    PYTHONPATH=src python -m benchmarks.autotune_bench [--quick]
        [--table-out PATH] [--no-write]

``--table-out`` defaults to the checked-in location
``src/repro/kernels/tuning_table.json``; ``--quick`` shrinks shapes and
candidate sets for the CI smoke job (parity still fully enforced).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.flash_attention import flash_mha
from repro.kernels.gcl_loss import gcl_pair_grads, gcl_pair_stats
from repro.kernels.ops import default_interpret
from repro.kernels.ref import gcl_pair_grads_ref, gcl_pair_stats_ref
from repro.models.attention import naive_attention

RANDOM_TOL = 1e-5

# (br, bc, d_block); d_block None = unblocked (whole d in VMEM)
GCL_CANDIDATES = [(128, 128, None), (128, 256, None), (256, 128, None),
                  (256, 256, None), (128, 128, 256)]
GCL_CANDIDATES_QUICK = [(128, 128, None), (128, 256, None)]
GCL_SHAPES = [(256, 512), (512, 512)]          # (b, d); square case
GCL_SHAPES_QUICK = [(256, 384)]

# (q_chunk, kv_chunk)
MHA_CANDIDATES = [(256, 512), (512, 1024), (512, 512), (1024, 1024)]
MHA_CANDIDATES_QUICK = [(128, 256), (256, 256)]
MHA_SHAPES = [(2, 512, 4, 64)]                 # (batch, seq, heads, hd)
MHA_SHAPES_QUICK = [(2, 256, 2, 64)]


def _time(f, *args, iters=3):
    jax.block_until_ready(f(*args))            # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return 1e6 * (time.perf_counter() - t0) / iters


def _bitwise(xs, ys):
    return all(bool(jnp.all(a == b)) for a, b in zip(xs, ys))


def _max_abs(xs, ys):
    return max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(xs, ys))


def _rand_gcl(b, d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    e1 = jax.random.normal(ks[0], (b, d))
    e2 = jax.random.normal(ks[1], (b, d))
    e1 = e1 / jnp.linalg.norm(e1, axis=-1, keepdims=True)
    e2 = e2 / jnp.linalg.norm(e2, axis=-1, keepdims=True)
    lwt = -jnp.abs(jax.random.normal(ks[2], (b,)))
    tau = jax.random.uniform(ks[3], (b,)) * 0.05 + 0.03
    return e1, e2, lwt, tau


def sweep_gcl(shapes, candidates, table, seed=0):
    """Parity-gate then time each (br, bc, d_block) for both gcl kernels;
    record the fastest passing config per (kernel, shape, dtype, backend).
    Returns (rows, ok)."""
    interp = default_interpret()
    backend = autotune.backend_key(interp)
    rows, ok = [], True
    for b, d in shapes:
        pe1, pe2, plwt, ptau = autotune.planted_gcl_case(b, d, seed)
        re1, re2, rlwt, rtau = _rand_gcl(b, d, seed)
        # the kernel takes lwt = log w - log tau; the ref oracle takes
        # log w and subtracts log tau itself — convert at the boundary
        plw = plwt + jnp.log(ptau)
        rlw = rlwt + jnp.log(rtau)
        oracle_s_p = gcl_pair_stats_ref(pe1, pe2, ptau, ptau)
        oracle_s_r = gcl_pair_stats_ref(re1, re2, rtau, rtau)
        oracle_g_p = gcl_pair_grads_ref(pe1, pe2, plw, plw, ptau, ptau)
        oracle_g_r = gcl_pair_grads_ref(re1, re2, rlw, rlw, rtau, rtau)
        best = {"gcl_stats": (None, float("inf")),
                "gcl_grads": (None, float("inf"))}
        for br, bc, dbk in candidates:
            tag = f"br={br},bc={bc},d_block={dbk}"
            kw = dict(interpret=interp, br=br, bc=bc, d_block=dbk)
            stats = jax.jit(lambda a, b2, t: tuple(
                gcl_pair_stats(a, b2, t, t, **kw)))
            grads = jax.jit(lambda a, b2, lw, t: tuple(
                gcl_pair_grads(a, b2, lw, lw, t, t, **kw)))
            for kern, fn, planted, p_orc, rand, r_orc in (
                    ("gcl_stats", stats, (pe1, pe2, ptau), oracle_s_p,
                     (re1, re2, rtau), oracle_s_r),
                    ("gcl_grads", grads, (pe1, pe2, plwt, ptau), oracle_g_p,
                     (re1, re2, rlwt, rtau), oracle_g_r)):
                name = f"autotune/{kern}/b={b}/d={d}/{tag}"
                if not _bitwise(fn(*planted), p_orc):
                    rows.append((name, 0.0, "ERROR:planted-bitwise-parity"))
                    ok = False
                    continue
                err = _max_abs(fn(*rand), r_orc)
                if err > RANDOM_TOL:
                    rows.append((name, 0.0,
                                 f"ERROR:random-parity:{err:.2e}"))
                    ok = False
                    continue
                us = _time(fn, *rand)
                rows.append((name, us, f"parity=bitwise+{err:.1e};"
                             f"backend={backend}"))
                if us < best[kern][1]:
                    best[kern] = ((br, bc, dbk), us)
        for kern, (cfg, us) in best.items():
            if cfg is None:
                continue
            br, bc, dbk = cfg
            table.record(kern, autotune.shape_bucket(b=b, cols=b, d=d),
                         jnp.float32, backend,
                         {"br": br, "bc": bc, "d_block": dbk}, us=us)
    return rows, ok


def sweep_mha(shapes, candidates, table, seed=0):
    """Parity-gate then time each (q_chunk, kv_chunk) for flash_mha.
    Parity covers forward AND grads (the chunks drive the remat backward);
    oracle = naive O(S^2) attention.  Returns (rows, ok)."""
    interp = default_interpret()
    backend = autotune.backend_key(interp)
    rows, ok = [], True
    for batch, seq, heads, hd in shapes:
        q, k, v, ct = autotune.planted_attention_case(batch, seq, heads,
                                                      hd, seed)
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        rq, rk, rv = (jax.random.normal(ks[i], (batch, seq, heads, hd))
                      / jnp.sqrt(hd) for i in range(3))
        rct = jax.random.normal(ks[3], (batch, seq, heads, hd))

        def fwd_bwd(f, args, cot):
            out, vjp = jax.vjp(f, *args)
            return (out,) + vjp(cot)

        orc_p = fwd_bwd(lambda a, b2, c: naive_attention(
            a, b2, c, causal=False), (q, k, v), ct)
        orc_r = fwd_bwd(lambda a, b2, c: naive_attention(
            a, b2, c, causal=True), (rq, rk, rv), rct)
        best = (None, float("inf"))
        for qc, kvc in candidates:
            name = f"autotune/flash_mha/S={seq}/hd={hd}/qc={qc}/kvc={kvc}"
            fp = jax.jit(lambda a, b2, c: fwd_bwd(
                lambda x, y, z: flash_mha(x, y, z, causal=False,
                                          interpret=interp, q_chunk=qc,
                                          kv_chunk=kvc), (a, b2, c), ct))
            fr = jax.jit(lambda a, b2, c: fwd_bwd(
                lambda x, y, z: flash_mha(x, y, z, causal=True,
                                          interpret=interp, q_chunk=qc,
                                          kv_chunk=kvc), (a, b2, c), rct))
            if not _bitwise(fp(q, k, v), orc_p):
                rows.append((name, 0.0, "ERROR:planted-bitwise-parity"))
                ok = False
                continue
            err = _max_abs(fr(rq, rk, rv), orc_r)
            if err > RANDOM_TOL:
                rows.append((name, 0.0, f"ERROR:random-parity:{err:.2e}"))
                ok = False
                continue
            us = _time(fr, rq, rk, rv)
            rows.append((name, us, f"parity=bitwise+{err:.1e};"
                         f"backend={backend}"))
            if us < best[1]:
                best = ((qc, kvc), us)
        if best[0] is not None:
            qc, kvc = best[0]
            table.record("flash_mha",
                         autotune.shape_bucket(sq=seq, sk=seq, hd=hd),
                         jnp.float32, backend,
                         {"q_chunk": qc, "kv_chunk": kvc}, us=best[1])
    return rows, ok


def run(steps=None, seed=0, quick=True, table_out=None, write=False):
    """Bench-harness entry point: sweep, return rows.  ``write=False`` by
    default so ``benchmarks.run`` never dirties the checked-in table; use
    ``main`` (or write=True) to persist."""
    table = autotune.TuningTable()
    r1, ok1 = sweep_gcl(GCL_SHAPES_QUICK if quick else GCL_SHAPES,
                        GCL_CANDIDATES_QUICK if quick else GCL_CANDIDATES,
                        table, seed)
    r2, ok2 = sweep_mha(MHA_SHAPES_QUICK if quick else MHA_SHAPES,
                        MHA_CANDIDATES_QUICK if quick else MHA_CANDIDATES,
                        table, seed)
    rows = r1 + r2
    if write:
        path = table.save(table_out)
        autotune.reset_cache()
        rows.append(("autotune/table", 0.0,
                     f"entries={len(table.entries)};path={path}"))
    rows.append(("autotune/parity", 0.0,
                 "OK" if (ok1 and ok2) else "ERROR:parity-failures"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes/candidate sets (CI smoke)")
    ap.add_argument("--table-out", default=None,
                    help="tuning-table path (default: the checked-in "
                         "src/repro/kernels/tuning_table.json)")
    ap.add_argument("--no-write", action="store_true",
                    help="sweep + parity only; do not persist the table")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows = run(seed=args.seed, quick=args.quick,
               table_out=args.table_out, write=not args.no_write)
    failed = False
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
        failed |= "ERROR" in str(derived)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
