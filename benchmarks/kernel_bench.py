"""Micro-benchmarks of the loss layer: fused Pallas GCL kernels
(interpret mode on CPU — correctness/compile surface, not TPU timing) vs
the pure-jnp reference path, plus the XLA-fused jnp path wall time."""
import time

import jax
import jax.numpy as jnp

from repro.core.losses import l2_normalize, row_stats
from repro.kernels.ref import gcl_pair_stats_ref


def _time(f, *args, iters=20):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return 1e6 * (time.perf_counter() - t0) / iters


def run(steps=None, seed=0):
    rows = []
    for B, d in [(512, 512), (1024, 512), (2048, 512)]:
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        e1 = l2_normalize(jax.random.normal(k1, (B, d)))
        e2 = l2_normalize(jax.random.normal(k2, (B, d)))
        tau = jnp.full((B,), 0.07)

        jnp_path = jax.jit(lambda a, b: tuple(
            row_stats(a, b, a, b, tau, tau)))
        us = _time(jnp_path, e1, e2)
        # derived: flops of the pair pass (2 sides x 2BBd)
        flops = 4.0 * B * B * d
        rows.append((f"gcl_stats/jnp/B={B}", us,
                     f"gflops_s={flops / us * 1e-3:.1f}"))
    return rows
