"""Micro-benchmarks of the loss layer: fused Pallas GCL kernels
(interpret mode on CPU — correctness/compile surface, not TPU timing) vs
the pure-jnp dense path.

Per batch size it reports wall time of both paths, a fused-vs-dense
parity column (max rel err of the stats), and the analytic HBM traffic of
the pair matrix per training step: the dense path materializes the (B, B)
f32 matrix ~8x per step (s1/s2 + exp'd h1/h2 in the forward, A1/A2 +
M1/M2 in the backward), while the fused kernels stream it through VMEM in
(128, 128) tiles — the pair matrix itself never reaches HBM."""
import time

import jax
import jax.numpy as jnp

from repro.core.losses import l2_normalize, row_stats
from repro.kernels.gcl_loss import gcl_pair_stats
from repro.kernels.ops import default_interpret


def _time(f, *args, iters=20):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return 1e6 * (time.perf_counter() - t0) / iters


def pair_matrix_bytes(B, impl):
    """Analytic HBM bytes touched by the (B, B) pair matrix per step."""
    if impl == "dense":
        return 8 * B * B * 4      # ~8 materializations, f32
    return 0                      # fused: tiles live in VMEM only


def run(steps=None, seed=0):
    rows = []
    for B, d in [(512, 512), (1024, 512), (2048, 512)]:
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        e1 = l2_normalize(jax.random.normal(k1, (B, d)))
        e2 = l2_normalize(jax.random.normal(k2, (B, d)))
        tau = jnp.full((B,), 0.07)

        jnp_path = jax.jit(lambda a, b: tuple(
            row_stats(a, b, a, b, tau, tau)))
        fused_path = jax.jit(lambda a, b: tuple(
            gcl_pair_stats(a, b, tau, tau, interpret=default_interpret())))

        us_dense = _time(jnp_path, e1, e2)
        us_fused = _time(fused_path, e1, e2, iters=5)

        # fused-vs-dense parity (max rel err over the four stats)
        out_d = jnp_path(e1, e2)
        out_f = fused_path(e1, e2)
        parity = max(
            float(jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1e-12)))
            for a, b in zip(out_f, out_d))

        # derived: flops of the pair pass (2 sides x 2BBd) + traffic model
        flops = 4.0 * B * B * d
        rows.append((f"gcl_stats/jnp/B={B}", us_dense,
                     f"gflops_s={flops / us_dense * 1e-3:.1f};"
                     f"pair_hbm_bytes={pair_matrix_bytes(B, 'dense')}"))
        rows.append((f"gcl_stats/fused/B={B}", us_fused,
                     f"gflops_s={flops / us_fused * 1e-3:.1f};"
                     f"pair_hbm_bytes={pair_matrix_bytes(B, 'fused')};"
                     f"parity_max_rel_err={parity:.2e}"))
    return rows
