"""Micro-benchmarks of the loss layer: fused Pallas GCL kernels
(interpret mode on CPU — correctness/compile surface, not TPU timing) vs
the pure-jnp dense path.

Per batch size it reports wall time of both paths, a fused-vs-dense
parity column (max rel err of the shift-decomposed stats), and the
analytic HBM traffic of the pair matrix per training step: the dense path
materializes the (B, B) f32 matrix ~8x per step (s1/s2 + shifted h1/h2 in
the forward, A1/A2 + M1/M2 in the backward), while the fused kernels
stream it through VMEM in (128, 128) tiles — the pair matrix itself never
reaches HBM.  Extra rows cover bf16 inputs (blocks stay bf16 in VMEM:
half the feature traffic, f32 accumulation) and the d-blocked BlockSpec
path for wide embeddings (d > VMEM tile budget)."""
import time

import jax
import jax.numpy as jnp

from repro.core.losses import l2_normalize, row_stats
from repro.kernels.gcl_loss import gcl_pair_stats
from repro.kernels.ops import default_interpret


def _time(f, *args, iters=20):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return 1e6 * (time.perf_counter() - t0) / iters


def pair_matrix_bytes(B, impl):
    """Analytic HBM bytes touched by the (B, B) pair matrix per step."""
    if impl == "dense":
        return 8 * B * B * 4      # ~8 materializations, f32
    return 0                      # fused: tiles live in VMEM only


def feature_tile_bytes(B, d, dtype_bytes):
    """Analytic HBM->VMEM feature traffic of one stats pass: each of the
    ceil(B/BR) row tiles re-streams the full (B, d) column set, and the
    row blocks themselves are read once."""
    from repro.kernels.gcl_loss import BR
    n_row_tiles = -(-B // BR)
    return (n_row_tiles + 1) * B * d * dtype_bytes


def run(steps=None, seed=0):
    rows = []
    for B, d in [(512, 512), (1024, 512), (2048, 512)]:
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        e1 = l2_normalize(jax.random.normal(k1, (B, d)))
        e2 = l2_normalize(jax.random.normal(k2, (B, d)))
        tau = jnp.full((B,), 0.07)

        jnp_path = jax.jit(lambda a, b: tuple(
            row_stats(a, b, a, b, tau, tau)))
        fused_path = jax.jit(lambda a, b: tuple(
            gcl_pair_stats(a, b, tau, tau, interpret=default_interpret())))

        us_dense = _time(jnp_path, e1, e2)
        us_fused = _time(fused_path, e1, e2, iters=5)

        # fused-vs-dense parity (max rel err over the six shifted stats)
        out_d = jnp_path(e1, e2)
        out_f = fused_path(e1, e2)
        parity = max(
            float(jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1e-12)))
            for a, b in zip(out_f, out_d))

        # derived: flops of the pair pass (2 sides x 2BBd) + traffic model
        flops = 4.0 * B * B * d
        rows.append((f"gcl_stats/jnp/B={B}", us_dense,
                     f"gflops_s={flops / us_dense * 1e-3:.1f};"
                     f"pair_hbm_bytes={pair_matrix_bytes(B, 'dense')}"))
        rows.append((f"gcl_stats/fused/B={B}", us_fused,
                     f"gflops_s={flops / us_fused * 1e-3:.1f};"
                     f"pair_hbm_bytes={pair_matrix_bytes(B, 'fused')};"
                     f"parity_max_rel_err={parity:.2e}"))

    # bf16 inputs: same kernel, bf16 blocks in VMEM, f32 accumulators
    B, d = 1024, 512
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    e1 = l2_normalize(jax.random.normal(k1, (B, d)))
    e2 = l2_normalize(jax.random.normal(k2, (B, d)))
    tau = jnp.full((B,), 0.07)
    f32_path = jax.jit(lambda a, b: tuple(
        gcl_pair_stats(a, b, tau, tau, interpret=default_interpret())))
    bf16_path = jax.jit(lambda a, b: tuple(gcl_pair_stats(
        a.astype(jnp.bfloat16), b.astype(jnp.bfloat16), tau, tau,
        interpret=default_interpret())))
    us_bf16 = _time(bf16_path, e1, e2, iters=5)
    out_32 = f32_path(e1, e2)
    out_16 = bf16_path(e1, e2)
    # compare in log domain (m + log g): scale-free across shift choices
    lg32 = out_32[4] + jnp.log(out_32[0])
    lg16 = out_16[4] + jnp.log(out_16[0])
    rows.append((f"gcl_stats/fused_bf16/B={B}", us_bf16,
                 f"feat_hbm_bytes={feature_tile_bytes(B, d, 2)};"
                 f"vs_f32_log_g_err={float(jnp.max(jnp.abs(lg16 - lg32))):.2e}"))

    # d-blocked path: wide embeddings, (BR, d_block) feature tiles
    B, d = 256, 4096
    e1 = l2_normalize(jax.random.normal(k1, (B, d)))
    e2 = l2_normalize(jax.random.normal(k2, (B, d)))
    tau = jnp.full((B,), 0.07)
    blocked = jax.jit(lambda a, b: tuple(gcl_pair_stats(
        a, b, tau, tau, interpret=default_interpret())))       # auto-blocks
    whole = jax.jit(lambda a, b: tuple(gcl_pair_stats(
        a, b, tau, tau, interpret=default_interpret(), d_block=d)))
    us_blk = _time(blocked, e1, e2, iters=5)
    parity = max(float(jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1e-12)))
                 for a, b in zip(blocked(e1, e2), whole(e1, e2)))
    rows.append((f"gcl_stats/fused_dblock/B={B}/d={d}", us_blk,
                 f"d_block=auto;vs_unblocked_max_rel_err={parity:.2e}"))
    return rows
